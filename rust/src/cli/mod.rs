//! Command-line interface (hand-rolled: the offline image has no `clap`).
//!
//! ```text
//! pagerank-nb run      --graph <src> --algo <variant> [--threads N]
//!                      [--storage mmap] [--shards S | --mem-budget MiB]
//!                      [--ooc-workers K] …
//! pagerank-nb serve    --graph <src> [--epochs N] [--batch N] [--readers N]
//! pagerank-nb bench    <exp-id|all> [--out DIR]
//! pagerank-nb bench-ci [--out FILE] [--baseline FILE] [--max-regress F]
//!                      [--seed-baseline | --require-baseline]
//! pagerank-nb gen      (--all | --dataset NAME) --out DIR
//! pagerank-nb info     --graph <src>
//! pagerank-nb validate --graph <src> [--threads N]
//! ```
//!
//! The full flag reference, with an example per subcommand, is in
//! `docs/cli.md`.
//!
//! Graph sources (`--graph`): a `.bin` binary cache, a SNAP edge-list text
//! file, or a generator spec — `web:N:DEG`, `social:N:DEG`, `road:N`,
//! `rmat:SCALE:EDGES`, `d:INDEX:DIVISOR`, `cycle:N`, `star:N`.

pub mod args;
pub mod commands;

pub use args::ArgMap;

use anyhow::{bail, Result};

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_usage();
        bail!("missing subcommand");
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "run" => commands::cmd_run(&ArgMap::parse(rest)?),
        "serve" => commands::cmd_serve(&ArgMap::parse(rest)?),
        "bench" => commands::cmd_bench(rest),
        "bench-ci" => commands::cmd_bench_ci(&ArgMap::parse(rest)?),
        "gen" => commands::cmd_gen(&ArgMap::parse(rest)?),
        "info" => commands::cmd_info(&ArgMap::parse(rest)?),
        "validate" => commands::cmd_validate(&ArgMap::parse(rest)?),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_usage() {
    eprintln!(
        "pagerank-nb — non-blocking PageRank for massive graphs

USAGE:
  pagerank-nb run      --graph <src> [--algo <variant>]
                       [--mode standard|pcpm|frontier|frontier-pcpm]
                       [--threads N] [--threshold X] [--iters N]
                       [--partition vertex|edge] [--top K] [--damping D]
                       [--delta-threshold X|auto] [--frontier-sched bitmap|worklist|hybrid]
                       [--numa off|pin|interleave]
                       [--pcpm-batch B] [--pcpm-layout compressed|slots]
                       [--storage memory|mmap] [--shards S | --mem-budget MiB]
                       [--ooc-workers K]
                       (--storage mmap runs against the v2 binary cache
                        zero-copy; --shards / --mem-budget sweep the graph
                        out-of-core with K shards resident at a time —
                        K workers claim dirty shards off a shared ring;
                        default min(threads, shards))
  pagerank-nb serve    --graph <src> [--mode frontier|frontier-pcpm]
                       [--epochs N] [--batch N] [--readers N] [--top K]
                       (evolve-query-reconverge loop: random edge batches,
                        incremental reconvergence, epoch-snapshotted queries)
  pagerank-nb bench    <table1|fig1..fig9|xla|ablation|all> [--out DIR]
                       [--scale DIVISOR] [--threads N] [--samples N]
  pagerank-nb bench-ci [--out FILE] [--baseline FILE] [--max-regress F]
                       [--scale DIVISOR] [--threads N] [--samples N]
                       [--seed-baseline | --require-baseline]
  pagerank-nb gen      (--all | --dataset NAME) --out DIR [--scale DIVISOR]
  pagerank-nb info     --graph <src>
  pagerank-nb validate --graph <src> [--threads N]

GRAPH SOURCES:
  path to .bin (binary cache) or SNAP edge-list text, or a generator spec:
  web:N:DEG  social:N:DEG  road:N  rmat:SCALE:EDGES  d:IDX:DIV  cycle:N  star:N

VARIANTS:
  sequential barrier barrier-identical barrier-edge barrier-opt wait-free
  no-sync no-sync-identical no-sync-edge no-sync-opt no-sync-opt-identical
  pcpm (partition-centric scatter-gather on compressed bin streams;
        tune --pcpm-batch / --pcpm-layout; also via --mode pcpm)
  frontier | frontier-pcpm (delta-scheduled gather; tune --delta-threshold
        (a number, or `auto` for residual-driven retuning), --frontier-sched
        (bitmap scan, claim-based work-list, or density-switching hybrid),
        and --pcpm-layout for frontier-pcpm; --numa pins workers node-local)
  xla-block (needs `make artifacts`)

Full flag reference with examples: docs/cli.md"
    );
}
