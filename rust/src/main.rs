//! `pagerank-nb` — leader binary: CLI over the non-blocking PageRank
//! library. See `pagerank-nb help` for usage.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = pagerank_nb::cli::dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
