#!/usr/bin/env bash
# Kick the tires: build the release binary and smoke-run one tiny graph
# through every engine mode (the paper's eleven CPU variants plus the
# partition-centric `pcpm` and the frontier/delta modes), then
# cross-validate all of them against the sequential oracle and smoke the
# ablation tables (including the pcpm/frontier rows). Mirrors the
# related-repo kick-tires pattern: fast, loud, and exercising every
# artifact a reviewer would touch.
#
# Usage: ./scripts/kick-tires.sh [GRAPH_SPEC]
#   GRAPH_SPEC defaults to web:800:6 (a ~800-vertex scale-free replica).

set -euo pipefail

cd "$(dirname "$0")/.."

GRAPH="${1:-web:800:6}"
THREADS="${THREADS:-4}"
BIN=target/release/pagerank-nb

echo "Starting Kick Tires (All)"

echo "── build ──"
cargo build --release

echo "── graph info ($GRAPH) ──"
"$BIN" info --graph "$GRAPH"

echo "── every variant + pcpm + frontier on $GRAPH ──"
for algo in sequential barrier barrier-identical barrier-edge barrier-opt \
            wait-free no-sync no-sync-identical no-sync-edge no-sync-opt \
            no-sync-opt-identical; do
    echo "· $algo"
    "$BIN" run --graph "$GRAPH" --algo "$algo" --threads "$THREADS" --top 3
done

echo "· pcpm (via --mode; compressed bin stream is the default)"
"$BIN" run --graph "$GRAPH" --mode pcpm --threads "$THREADS" --top 3

echo "· pcpm (batched scatter: 2 source partitions per worker)"
"$BIN" run --graph "$GRAPH" --mode pcpm --pcpm-batch 2 --threads "$THREADS" --top 3

echo "· pcpm (per-edge slots baseline via --pcpm-layout)"
"$BIN" run --graph "$GRAPH" --mode pcpm --pcpm-layout slots --threads "$THREADS" --top 3

echo "· frontier (via --mode, explicit delta threshold)"
"$BIN" run --graph "$GRAPH" --mode frontier --threads "$THREADS" \
    --delta-threshold 1e-11 --top 3

echo "· frontier-pcpm (via --mode; compressed delta scatter)"
"$BIN" run --graph "$GRAPH" --mode frontier-pcpm --threads "$THREADS" --top 3

echo "· frontier-pcpm (per-edge slots baseline)"
"$BIN" run --graph "$GRAPH" --mode frontier-pcpm --pcpm-layout slots \
    --threads "$THREADS" --top 3

echo "· frontier (claim-based work-list scheduler)"
"$BIN" run --graph "$GRAPH" --mode frontier --frontier-sched worklist \
    --threads "$THREADS" --top 3

echo "· frontier-pcpm (hybrid density-switching scheduler)"
"$BIN" run --graph "$GRAPH" --mode frontier-pcpm --frontier-sched hybrid \
    --threads "$THREADS" --top 3

echo "· frontier (residual-driven delta autotuning)"
"$BIN" run --graph "$GRAPH" --mode frontier --delta-threshold auto \
    --threads "$THREADS" --top 3

echo "· frontier (NUMA-pinned workers; single-node fallback on laptops/CI)"
"$BIN" run --graph "$GRAPH" --mode frontier --numa pin \
    --threads "$THREADS" --top 3

echo "· out-of-core (mmap-backed v2 cache, 4-shard rotation)"
"$BIN" run --graph "$GRAPH" --storage mmap --shards 4 --top 3

echo "· out-of-core (parallel: 2 claim-ring workers over 4 shards)"
"$BIN" run --graph "$GRAPH" --storage mmap --shards 4 --ooc-workers 2 --top 3

echo "· out-of-core (shard count derived from a 1 MiB memory budget)"
"$BIN" run --graph "$GRAPH" --storage mmap --mem-budget 1 --top 3

echo "· serve (evolve-query-reconverge: incremental epochs + live queries)"
"$BIN" serve --graph "$GRAPH" --epochs 2 --batch 16 --readers 2 \
    --threads "$THREADS" --top 3

echo "── cross-validation against the sequential oracle ──"
"$BIN" validate --graph "$GRAPH" --threads "$THREADS"

echo "── ablation smoke (partition-policy and scheduling rows) ──"
PAGERANK_NB_SCALE="${ABLATION_SCALE:-20000}" "$BIN" bench ablation \
    --threads 2 --samples 1 --out "${ABLATION_OUT:-reports/kick-tires}"

echo "Full flag reference with an example per subcommand: docs/cli.md"
echo "Kick tires passed."
