#!/usr/bin/env bash
# Concurrency-hygiene audit — the static half of the model-checking PR
# (docs/concurrency.md §Static audit). Three rules:
#
#   1. `unsafe` without `// SAFETY:` within 8 lines   (rust/src + rust/vendor)
#   2. `Ordering::Relaxed` outside rust/src/sync/ without a `// relaxed:`
#      justification within 3 lines                   (rust/src)
#   3. `std::sync::atomic` named anywhere but sync/shim.rs — atomics must
#      flow through the shim so `--features pallas-model` can interpose
#      the model checker                              (rust/src)
#
# When a cargo toolchain is present the audit runs `pagerank-lint`
# (rust/tools/lint), the canonical implementation with unit tests; without
# one it falls back to the awk implementation below — same rules, so the
# gate also works on toolchain-less hosts. AUDIT_NO_CARGO=1 forces the
# fallback (used to test the awk path on CI).
#
# Exit: 0 clean, 1 with file:line diagnostics on stderr otherwise.

set -euo pipefail
cd "$(dirname "$0")/.."

if command -v cargo >/dev/null 2>&1 && [ "${AUDIT_NO_CARGO:-0}" != "1" ]; then
    exec cargo run -q -p pagerank-lint -- .
fi

status=0

# Rule 1: every unsafe needs a SAFETY comment nearby.
# shellcheck disable=SC2044  # tree has no exotic filenames
for f in $(find rust/src rust/vendor -name '*.rs' -path '*src*' | sort); do
    awk -v file="$f" '
        { lines[FNR] = $0 }
        {
            t = $0; sub(/^[ \t]+/, "", t)
            if (t ~ /^\/\//) next                      # whole-line comment
            code = $0; sub(/\/\/.*$/, "", code)        # strip trailing comment
            if (code !~ /(^|[^A-Za-z0-9_])unsafe([^A-Za-z0-9_]|$)/) next
            if ($0 ~ /unsafe_op_in_unsafe_fn|unsafe_code|forbid\(unsafe/) next
            ok = 0
            for (i = FNR - 8; i <= FNR; i++)
                if (i >= 1 && lines[i] ~ /SAFETY:/) ok = 1
            if (!ok) {
                printf "%s:%d: `unsafe` without a `// SAFETY:` comment within 8 lines\n", file, FNR > "/dev/stderr"
                bad = 1
            }
        }
        END { exit bad }
    ' "$f" || status=1
done

# Rule 2: Relaxed outside the sync/ substrate needs a written excuse.
for f in $(find rust/src -name '*.rs' -not -path 'rust/src/sync/*' | sort); do
    awk -v file="$f" '
        { lines[FNR] = $0 }
        {
            t = $0; sub(/^[ \t]+/, "", t)
            if (t ~ /^\/\//) next
            code = $0; sub(/\/\/.*$/, "", code)
            if (code !~ /Ordering::Relaxed/) next
            ok = 0
            for (i = FNR - 3; i <= FNR; i++)
                if (i >= 1 && lines[i] ~ /\/\/ relaxed:/) ok = 1
            if (!ok) {
                printf "%s:%d: Ordering::Relaxed outside sync/ without a `// relaxed: <why>` comment within 3 lines\n", file, FNR > "/dev/stderr"
                bad = 1
            }
        }
        END { exit bad }
    ' "$f" || status=1
done

# Rule 3: the atomic-import funnel.
for f in $(find rust/src -name '*.rs' ! -path 'rust/src/sync/shim.rs' | sort); do
    awk -v file="$f" '
        {
            t = $0; sub(/^[ \t]+/, "", t)
            if (t ~ /^\/\//) next
            code = $0; sub(/\/\/.*$/, "", code)
            if (code !~ /std::sync::atomic/) next
            printf "%s:%d: direct `std::sync::atomic` use — route atomics through `crate::sync::shim`\n", file, FNR > "/dev/stderr"
            bad = 1
        }
        END { exit bad }
    ' "$f" || status=1
done

if [ "$status" -eq 0 ]; then
    echo "audit-unsafe: clean"
else
    echo "audit-unsafe: violations found (rules in docs/concurrency.md)" >&2
fi
exit "$status"
